"""Runtime sanitizers: the warm device paths must run without implicit
host<->device transfers, and the quantized-shape grid must bound the
fused fold's compiled-executable count."""

import jax
import numpy as np
import pytest

from repro.analysis.sanitize import (
    ImplicitTransferError,
    jit_cache_size,
    no_implicit_transfers,
)
from repro.core.batched_query import batched_query
from repro.core.cluster_index import build_cluster_index
from repro.core.device_engine import _fused_fold, _quantize, device_counts, device_index
from repro.core.queries import ConjunctiveQueries
from repro.core.reorder import cluster_ranges, reorder_permutation
from repro.data.corpus import Corpus
from repro.index.build import build_index, permute_docs


@pytest.fixture(scope="module")
def cidx():
    rng = np.random.default_rng(42)
    n_docs, n_terms, k = 220, 90, 6
    rows, ptr = [], [0]
    for _ in range(n_docs):
        r = np.unique(rng.integers(0, n_terms, 18))
        rows.append(r)
        ptr.append(ptr[-1] + len(r))
    corpus = Corpus(
        doc_ptr=np.asarray(ptr, np.int64),
        doc_terms=np.concatenate(rows).astype(np.int32),
        n_terms=n_terms,
    )
    assign = rng.integers(0, k, n_docs)
    perm = reorder_permutation(assign, k)
    ranges = cluster_ranges(assign, k)
    reordered = permute_docs(build_index(corpus), perm)
    return build_cluster_index(reordered, ranges)


def _queries(rng, n_q, n_terms, max_arity=4):
    lists = [
        rng.integers(0, n_terms, int(rng.integers(1, max_arity + 1))).tolist()
        for _ in range(n_q)
    ]
    return ConjunctiveQueries.from_lists(lists)


def test_guard_catches_implicit_transfers():
    x = jax.device_put(np.arange(8, dtype=np.int32))
    h = np.arange(8, dtype=np.int32)
    with no_implicit_transfers():
        with pytest.raises(ImplicitTransferError):
            np.asarray(x)  # implicit device->host
        with pytest.raises(ImplicitTransferError):
            jax.numpy.asarray(h)  # implicit host->device
        # explicit transfers stay legal
        back = jax.device_get(x)
        np.testing.assert_array_equal(back, np.arange(8))
        _ = jax.device_put(back)
    # outside the guard everything is back to normal
    np.testing.assert_array_equal(np.asarray(x), np.arange(8))


def test_guard_restores_on_exception():
    before = (np.asarray, jax.numpy.asarray, jax.device_get, jax.device_put)
    with pytest.raises(RuntimeError, match="boom"):
        with no_implicit_transfers():
            raise RuntimeError("boom")
    assert (np.asarray, jax.numpy.asarray, jax.device_get, jax.device_put) == before


def test_warm_device_counts_has_no_implicit_transfers(cidx):
    rng = np.random.default_rng(3)
    cq = _queries(rng, 24, cidx.index.n_terms)
    counts_ref, _ = device_counts(cidx, cq)  # warm: upload + compile
    with no_implicit_transfers():
        counts, info = device_counts(cidx, cq)
        counts2, docs, _ = device_counts(cidx, cq, return_docs=True)
    np.testing.assert_array_equal(counts, counts_ref)
    np.testing.assert_array_equal(counts2, counts_ref)
    assert info["n_kernel_calls"] == 1.0
    # cross-check against the host loop (outside the guard)
    ptr, docs_ref, _w = batched_query(cidx, cq)
    np.testing.assert_array_equal(counts, np.diff(ptr))
    np.testing.assert_array_equal(docs, docs_ref)


def test_warm_search_service_device_path_is_clean(cidx):
    from repro.serve.search_service import SearchService

    class _Res:
        cluster_index = cidx

    _Res.cluster_index = cidx
    svc = SearchService(_Res())
    rng = np.random.default_rng(9)
    cq = _queries(rng, 16, cidx.index.n_terms)
    ref, _ = svc.serve_counts_device(cq)  # warm
    with no_implicit_transfers():
        counts, _info = svc.serve_counts_device(cq)
    np.testing.assert_array_equal(counts, ref)


def test_warm_sharded_counts_has_no_implicit_transfers(cidx):
    from repro.core.device_engine import (
        shard_mesh,
        sharded_device_counts,
        sharded_device_index,
    )

    rng = np.random.default_rng(5)
    cq = _queries(rng, 20, cidx.index.n_terms)
    sidx = sharded_device_index(cidx, mesh=shard_mesh(4))
    ref, _ = sharded_device_counts(cidx, cq, sidx=sidx)  # warm
    with no_implicit_transfers():
        counts, info = sharded_device_counts(cidx, cq, sidx=sidx)
        counts2, docs, _ = sharded_device_counts(
            cidx, cq, sidx=sidx, return_docs=True
        )
    np.testing.assert_array_equal(counts, ref)
    np.testing.assert_array_equal(counts2, ref)
    assert info["n_shards"] == 4.0


def test_quantized_grid_bounds_compile_count(cidx):
    """N batches of drifting sizes must compile at most as many
    executables as there are distinct quantized shape keys — the whole
    point of _quantize as the jit cache key."""
    rng = np.random.default_rng(7)
    n_terms = cidx.index.n_terms
    device_index(cidx)  # upload once
    before = jit_cache_size(_fused_fold)
    sizes = [20, 21, 22, 23, 24, 25, 26, 27]  # drifting batch sizes
    batches = [_queries(rng, n, n_terms, max_arity=3) for n in sizes]
    for n_q, cq in zip(sizes, batches, strict=True):
        counts, _ = device_counts(cidx, cq)
        assert len(counts) == n_q
    grown = jit_cache_size(_fused_fold) - before
    # The cache key is the *quantized* shape tuple, so drifting sizes
    # must share executables: strictly fewer compiles than batches.
    assert 0 < grown < len(sizes)
    # And the key is a pure function of the quantized shapes: replaying
    # every batch compiles nothing new.
    for cq in batches:
        device_counts(cidx, cq)
    assert jit_cache_size(_fused_fold) - before == grown


def test_quantize_is_monotone_padding():
    for n in (1, 5, 8, 100, 1000, 12345):
        q = _quantize(n)
        assert q >= n and q % 8 == 0
