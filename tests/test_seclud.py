import numpy as np
import pytest

from repro.core.seclud import SecludPipeline


@pytest.fixture(scope="module")
def fitted(small_corpus, small_log):
    pipe = SecludPipeline(tc=800, doc_grained_below=256, seed=0)
    res = pipe.fit(small_corpus, k=8, algo="topdown", log=small_log)
    return pipe, res


def test_fit_shape(fitted, small_corpus):
    pipe, res = fitted
    assert res.assign.shape == (small_corpus.n_docs,)
    assert 8 <= res.k <= 17
    assert res.psi <= res.psi_single  # clustering never hurts ψ (min model)
    assert res.ranges[-1] == small_corpus.n_docs


def test_evaluate_lossless_and_speedups(fitted, small_corpus, small_log):
    pipe, res = fitted
    ev = pipe.evaluate(small_corpus, res, small_log, max_queries=120)
    # losslessness is asserted inside evaluate(); here check the report.
    assert ev["S_T"] >= 1.0 - 1e-9
    assert ev["work_baseline"] > 0
    assert ev["n_queries"] == 120
    assert ev["S_C"] > 0 and ev["S_R"] > 0


def test_evaluate_batched_matches_loop(fitted, small_corpus, small_log):
    """The batched fast path is bit-identical on every shared work metric
    and adds wall-clock timings."""
    pipe, res = fitted
    ev_loop = pipe.evaluate(small_corpus, res, small_log, max_queries=120)
    ev_fast = pipe.evaluate(
        small_corpus, res, small_log, max_queries=120, batched=True
    )
    for key in ev_loop:
        assert ev_fast[key] == ev_loop[key], key
    for key in ("t_baseline_s", "t_cluster_index_s", "t_reordered_s"):
        assert ev_fast[key] >= 0.0


def test_evaluate_max_queries_zero(fitted, small_corpus, small_log):
    """Regression (satellite 1): max_queries=0 means zero queries, not the
    whole log falling through an `if max_queries` truthiness check."""
    pipe, res = fitted
    for batched in (False, True):
        ev = pipe.evaluate(
            small_corpus, res, small_log, max_queries=0, batched=batched
        )
        assert ev["n_queries"] == 0
        assert ev["work_baseline"] == 0


def test_flat_algo_also_works(small_corpus, small_log):
    pipe = SecludPipeline(tc=400, doc_grained_below=256, seed=0)
    res = pipe.fit(small_corpus, k=4, algo="flat", log=small_log)
    assert res.k == 4
    ev = pipe.evaluate(small_corpus, res, small_log, max_queries=40)
    assert ev["S_T"] >= 1.0 - 1e-9


def test_corpus_probabilities_fallback(small_corpus):
    pipe = SecludPipeline(tc=400, doc_grained_below=128, seed=0)
    res = pipe.fit(small_corpus, k=4, algo="topdown")  # no log: corpus stats
    assert res.k >= 4
