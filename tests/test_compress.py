import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis, or fallback

from repro.index.build import build_index
from repro.index.compress import (
    decode_gaps,
    encode_gaps,
    gaps_of,
    golomb_parameter,
    index_bits_per_posting,
    posting_bits,
)


def test_gaps_roundtrip():
    postings = np.array([0, 3, 4, 10, 100])
    g = gaps_of(postings)
    assert np.array_equal(np.cumsum(g) - 1, postings)
    assert np.all(g >= 1)


@pytest.mark.parametrize("code", ["gamma", "delta", "varbyte"])
def test_encode_decode_roundtrip(code, rng):
    gaps = rng.integers(1, 10_000, size=200)
    packed, nbits = encode_gaps(gaps, code)
    got = decode_gaps(packed, nbits, len(gaps), code)
    assert np.array_equal(got, gaps)


def test_golomb_roundtrip(rng):
    for b in (1, 2, 3, 7, 16, 100):
        gaps = rng.integers(1, 5_000, size=100)
        packed, nbits = encode_gaps(gaps, "golomb", b=b)
        got = decode_gaps(packed, nbits, len(gaps), "golomb", b=b)
        assert np.array_equal(got, gaps)


@settings(max_examples=40, deadline=None)
@given(
    st.lists(st.integers(1, 1 << 30), min_size=1, max_size=60),
    st.sampled_from(["gamma", "delta", "varbyte"]),
)
def test_encode_decode_property(gaps, code):
    gaps = np.asarray(gaps, dtype=np.int64)
    packed, nbits = encode_gaps(gaps, code)
    assert np.array_equal(decode_gaps(packed, nbits, len(gaps), code), gaps)


@settings(max_examples=40, deadline=None)
@given(
    st.lists(st.integers(1, 512), min_size=1, max_size=60),
    st.integers(1, 4096),
)
def test_golomb_roundtrip_property(quotient_scale, b):
    """Golomb round-trips for ANY parameter b >= 1, not just the tuned
    values — the truncated-binary remainder path has off-by-one room.
    Gaps are drawn relative to b (unary quotient <= 512 bits) so the
    bit-at-a-time reference encoder stays fast while still covering every
    remainder / quotient combination that matters."""
    rng = np.random.default_rng(len(quotient_scale) * 4099 + b)
    q = np.asarray(quotient_scale, dtype=np.int64) - 1
    r = rng.integers(0, b, size=len(q))
    gaps = q * b + r + 1  # every (quotient, remainder) pair reachable
    packed, nbits = encode_gaps(gaps, "golomb", b=b)
    assert np.array_equal(decode_gaps(packed, nbits, len(gaps), "golomb", b=b), gaps)


@settings(max_examples=40, deadline=None)
@given(
    st.lists(st.integers(0, 1 << 20), min_size=1, max_size=80, unique=True),
    st.sampled_from(["gamma", "delta", "varbyte", "golomb"]),
)
def test_posting_bits_equals_packed_length_property(doc_ids, code):
    """The vectorized bit COUNT must equal the measured length of the
    bit-exact encoder's output, for every code, on arbitrary doc-id sets."""
    postings = np.sort(np.asarray(doc_ids, dtype=np.int64))
    n_docs = int(postings[-1]) + 1
    counted = posting_bits(postings, n_docs, code)
    b = golomb_parameter(n_docs, len(postings)) if code == "golomb" else None
    packed, nbits = encode_gaps(gaps_of(postings), code, b=b)
    assert counted == nbits
    # and the packed array really holds exactly ceil(nbits / 8) bytes
    assert len(packed) == -(-nbits // 8)
    got = decode_gaps(packed, nbits, len(postings), code, b=b)
    assert np.array_equal(np.cumsum(got) - 1, postings)


def test_bit_count_matches_encoder(rng):
    """Vectorized bit counting == exact encoder length."""
    postings = np.sort(rng.choice(100_000, size=500, replace=False))
    n_docs = 100_000
    for code in ("gamma", "delta", "varbyte"):
        counted = posting_bits(postings, n_docs, code)
        _, nbits = encode_gaps(gaps_of(postings), code)
        assert counted == nbits
    b = golomb_parameter(n_docs, len(postings))
    counted = posting_bits(postings, n_docs, "golomb")
    _, nbits = encode_gaps(gaps_of(postings), "golomb", b=b)
    assert counted == nbits


def test_clustered_order_compresses_better(rng):
    """Appendix A's effect: cluster-contiguous (skewed-gap) posting lists
    compress better under Elias codes than uniformly random ids."""
    n_docs = 1 << 16
    ln = 4096
    uniform = np.sort(rng.choice(n_docs, ln, replace=False))
    # Clustered: the same number of postings packed into 10% of the space.
    lo = rng.choice(n_docs // 8, 1)[0]
    clustered = np.sort(rng.choice(n_docs // 10, ln, replace=False)) + lo
    for code in ("gamma", "delta"):
        assert posting_bits(clustered, n_docs, code) < posting_bits(
            uniform, n_docs, code
        )


def test_index_bits_per_posting(small_corpus):
    idx = build_index(small_corpus)
    out = index_bits_per_posting(idx, codes=("gamma", "golomb", "raw"))
    assert out["raw"] == 32.0
    assert 0 < out["gamma"] < 32
    assert 0 < out["golomb"] < 32
