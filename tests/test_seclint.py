"""seclint: every rule must trip on its committed fixture, and the real
source tree must be clean.  The fixtures are the linter's regression
suite — a rule that stops firing on them has silently died."""

import subprocess
import sys
from pathlib import Path

from repro.analysis.lint import (
    RULES,
    check_kernel_contracts,
    lint_paths,
    lint_source,
)

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "fixtures" / "seclint" / "bad"
SRC = REPO / "src"


def _rules_of(findings):
    return {f.rule for f in findings}


def test_fixture_trips_every_rule():
    findings = lint_paths([FIXTURES], tests_dir=None)
    assert _rules_of(findings) == set(RULES), (
        "each SEC rule must fire on the bad fixture tree; "
        f"got {sorted(_rules_of(findings))}"
    )


def test_src_tree_is_clean():
    findings = lint_paths([SRC], tests_dir=REPO / "tests")
    assert findings == [], "\n".join(str(f) for f in findings)


def test_sec001_host_sync_in_traced_code():
    src = (FIXTURES / "core" / "device_engine.py").read_text()
    f = [x for x in lint_source(src, "pkg/core/device_engine.py") if x.rule == "SEC001"]
    assert len(f) >= 4  # if-branch, int(), .item(), np.asarray
    msgs = " ".join(x.message for x in f)
    assert ".item()" in msgs and "np.asarray" in msgs


def test_sec001_static_shape_reads_are_exempt():
    src = """\
import jax

@jax.jit
def f(x):
    if x.ndim == 2:          # static under trace: fine
        n = x.shape[0]       # static: fine
    return x * n
"""
    assert lint_source(src, "pkg/core/device_engine.py") == []


def test_sec001_scalar_annotations_are_exempt():
    src = """\
import jax

@jax.jit
def f(x, n: int, w: int | None = None):
    if n > 3 and w is not None:
        return x + w
    return x
"""
    assert lint_source(src, "pkg/core/device_engine.py") == []


def test_sec002_recompilation_hazards():
    src = (FIXTURES / "core" / "device_engine.py").read_text()
    f = [x for x in lint_source(src, "pkg/core/device_engine.py") if x.rule == "SEC002"]
    msgs = " ".join(x.message for x in f)
    assert "immediately-invoked" in msgs
    assert "unhashable" in msgs
    assert len(f) >= 3


def test_sec002_partial_binding_is_not_flagged():
    # partial(jax.jit, ...)(f) at module level is jit *construction*, the
    # idiom the engine itself uses — it must not read as an invocation.
    src = """\
import functools, jax

def _core(cells, n_queries_pad):
    return cells[:n_queries_pad]

_fused = functools.partial(jax.jit, static_argnames=("n_queries_pad",))(_core)
"""
    assert lint_source(src, "pkg/core/device_engine.py") == []


def test_sec003_literal_sentinels():
    src = (FIXTURES / "core" / "device_engine.py").read_text()
    f = [x for x in lint_source(src, "pkg/core/device_engine.py") if x.rule == "SEC003"]
    assert len(f) >= 2  # the fill and the comparison


def test_sec003_only_in_device_data_paths():
    # The rule is scoped to the engine's data-path modules; -1 in, say,
    # the data loaders is ordinary arithmetic and must not be flagged.
    src = "def f(offset):\n    return offset == -1\n"
    assert lint_source(src, "pkg/data/corpus.py") == []


def test_sec005_jit_in_request_path():
    src = (FIXTURES / "serve" / "loop.py").read_text()
    f = lint_source(src, "pkg/serve/loop.py")
    sec5 = [x for x in f if x.rule == "SEC005"]
    assert len(sec5) >= 2  # direct jax.jit and partial(jax.jit, ...)
    assert all("request path" in x.message for x in sec5)
    # the fixture must trip *only* SEC005 — its sins are pure
    assert {x.rule for x in f} == {"SEC005"}


def test_sec005_scoped_to_serve_modules():
    src = (FIXTURES / "serve" / "loop.py").read_text()
    # identical code outside serve/ is the engine's own business
    assert all(
        x.rule != "SEC005" for x in lint_source(src, "pkg/core/engine.py")
    )


def test_sec005_startup_bindings_are_exempt():
    src = """\
import functools

import jax


def _fold(counts):
    return counts.sum()


# module-level binding: constructed once at import, prewarmable — fine
_jitted = jax.jit(_fold)


@functools.lru_cache(maxsize=None)
def _build_fold(n_shards):
    # cached builder: constructs once per config, the engine's pattern
    return jax.jit(functools.partial(_fold))


async def handle(batch):
    return _jitted(batch)
"""
    assert lint_source(src, "pkg/serve/loop.py") == []


def test_sec006_resilience_fixture():
    src = (FIXTURES / "serve" / "resilience.py").read_text()
    f = lint_source(src, "pkg/serve/resilience.py")
    sec6 = [x for x in f if x.rule == "SEC006"]
    assert len(sec6) == 3  # bare except, swallow, unbounded while True
    msgs = " ".join(x.message for x in sec6)
    assert "bare `except:`" in msgs
    assert "swallows" in msgs
    assert "unbounded" in msgs
    # the fixture must trip *only* SEC006 — its sins are pure
    assert {x.rule for x in f} == {"SEC006"}


def test_sec006_scoped_to_fault_path_modules():
    # Identical code outside serve/ and dist/ is not the resilience
    # layer's business (a data loader may reasonably best-effort skip).
    src = (FIXTURES / "serve" / "resilience.py").read_text()
    assert lint_source(src, "pkg/data/loader.py") == []
    # but dist/ is in scope alongside serve/
    assert any(
        x.rule == "SEC006"
        for x in lint_source(src, "pkg/dist/fault_tolerance.py")
    )


def test_sec006_bounded_handling_is_exempt():
    # The sanctioned shapes: a bounded for-retry that re-raises on
    # exhaustion, an except that *records* the failure, a while True
    # with a reachable exit.  None of these defeat the ladder.
    src = """\
def bounded_retry(engine, batch, budget):
    last = None
    for attempt in range(budget):
        try:
            return engine(batch)
        except Exception as err:
            last = err
    raise last


def serve_loop(queue):
    while True:
        item = queue.get()
        if item is None:
            break
        handle(item)


def pump(step):
    while True:
        try:
            step()
        except Exception as err:
            raise RuntimeError("step failed") from err  # raise is an exit
"""
    assert lint_source(src, "pkg/serve/loop.py") == []


def test_sec006_nested_loop_break_does_not_exempt():
    # A break belonging to an inner for-loop never exits the outer
    # while True — the spin is still unbounded.
    src = """\
def drain(shards):
    while True:
        for s in shards:
            if s.empty():
                break
            s.pump()
"""
    f = lint_source(src, "pkg/serve/loop.py")
    assert [x.rule for x in f] == ["SEC006"]


def test_sec004_kernel_contract():
    f = check_kernel_contracts(FIXTURES / "kernels", tests_dir=None)
    assert {x.rule for x in f} == {"SEC004"}
    msgs = " ".join(x.message for x in f)
    assert "ref.py" in msgs and "ops.py" in msgs


def test_sec004_real_kernels_are_complete():
    f = check_kernel_contracts(SRC / "repro" / "kernels", tests_dir=REPO / "tests")
    assert f == [], "\n".join(str(f_) for f_ in f)


def test_cli_selftest_and_exit_codes():
    tool = REPO / "tools" / "seclint.py"
    r = subprocess.run(
        [sys.executable, str(tool), "--selftest"],
        capture_output=True, text=True, cwd=REPO,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "selftest: OK" in r.stdout
    # linting the bad fixtures directly must fail with findings (exit 1)
    r = subprocess.run(
        [sys.executable, str(tool), str(FIXTURES), "--tests-dir", ""],
        capture_output=True, text=True, cwd=REPO,
    )
    assert r.returncode == 1
    assert "SEC00" in r.stdout
    # and the real tree must pass (exit 0)
    r = subprocess.run(
        [sys.executable, str(tool), "src"],
        capture_output=True, text=True, cwd=REPO,
    )
    assert r.returncode == 0, r.stdout + r.stderr
