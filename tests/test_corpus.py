import numpy as np

from repro.data.corpus import CorpusSpec, synth_corpus, corpus_stats
from repro.data.query_log import synth_query_log, term_probabilities


def test_corpus_csr_invariants(small_corpus):
    c = small_corpus
    assert c.doc_ptr[0] == 0
    assert c.doc_ptr[-1] == len(c.doc_terms)
    assert np.all(np.diff(c.doc_ptr) >= 0)
    # Terms sorted + unique within every document.
    for d in range(0, c.n_docs, 97):
        terms = c.doc(d)
        assert np.all(np.diff(terms) > 0)
        assert terms.min() >= 0 and terms.max() < c.n_terms


def test_corpus_deterministic():
    spec = CorpusSpec(n_docs=200, n_terms=500, seed=3)
    a, b = synth_corpus(spec), synth_corpus(spec)
    assert np.array_equal(a.doc_terms, b.doc_terms)
    assert np.array_equal(a.doc_ptr, b.doc_ptr)


def test_corpus_zipf_marginal():
    spec = CorpusSpec(n_docs=3000, n_terms=2000, mean_doc_len=50, seed=0,
                      topicality=0.0)
    c = synth_corpus(spec)
    df = c.term_doc_freq().astype(float)
    # Rank-1 term should dominate; df roughly decreasing in rank.
    top = df[:10].mean()
    mid = df[100:110].mean()
    tail = df[1000:1100].mean()
    assert top > mid > tail


def test_corpus_topic_structure():
    spec = CorpusSpec(n_docs=2000, n_terms=2000, n_topics=4, topicality=0.8,
                      topic_boost=100.0, seed=1)
    c = synth_corpus(spec)
    # Docs of the same topic share more mid-band terms than across topics.
    hi = spec.topic_block_hi or spec.n_terms // 2
    lo = spec.topic_block_lo
    block = (hi - lo) // 4
    counts = np.zeros((4, 4))
    docs = np.repeat(np.arange(c.n_docs), np.diff(c.doc_ptr))
    for z in range(4):
        sel = (c.doc_terms >= lo + z * block) & (c.doc_terms < lo + (z + 1) * block)
        topic_of_doc = c.doc_topic[docs[sel]]
        for z2 in range(4):
            counts[z, z2] = (topic_of_doc == z2).sum()
    # Diagonal dominance: topical terms come mostly from their own topic.
    assert np.all(np.diag(counts) > 0.5 * counts.sum(axis=1))


def test_subset_roundtrip(small_corpus):
    ids = np.array([3, 10, 500, 1400])
    sub = small_corpus.subset(ids)
    assert sub.n_docs == 4
    for i, d in enumerate(ids):
        assert np.array_equal(sub.doc(i), small_corpus.doc(int(d)))


def test_query_log(small_corpus, small_log):
    q = small_log.queries
    assert q.shape[1] == 2
    assert np.all(q[:, 0] != q[:, 1])
    df = small_corpus.term_doc_freq()
    assert np.all(df[q.ravel()] > 0)  # no empty-list terms
    stats = small_log.stats()
    assert stats["queries"] == len(q)


def test_term_probabilities(small_corpus, small_log):
    p_log = term_probabilities(small_corpus.n_terms, log=small_log)
    p_corp = term_probabilities(small_corpus.n_terms, corpus=small_corpus)
    for p in (p_log, p_corp):
        assert p.shape == (small_corpus.n_terms,)
        assert abs(p.sum() - 1.0) < 1e-9
        assert np.all(p >= 0)
