#!/usr/bin/env python3
"""seclint CLI — run the repo's static invariant rules (SEC001–SEC005).

Usage:
    python tools/seclint.py                # lint src/ (the default)
    python tools/seclint.py src tests      # lint explicit trees
    python tools/seclint.py --selftest     # prove every rule trips on
                                           # the committed bad fixtures
    python tools/seclint.py --list-rules

Exit status: 0 when no findings, 1 otherwise.  The engine lives in
``repro.analysis.lint``; this wrapper only resolves paths and formats
output, and bootstraps ``src/`` onto ``sys.path`` so it runs from a
plain checkout without installation (and without jax — the lint rules
are stdlib-ast only).
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
if str(REPO_ROOT / "src") not in sys.path:
    sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.analysis import lint  # noqa: E402

FIXTURES = REPO_ROOT / "tests" / "fixtures" / "seclint" / "bad"


def _default_tests_dir(paths) -> Path | None:
    """The tests/ directory enabling SEC004's kernel≡ref test check:
    sibling of the first scanned tree's repo root, else the CWD's."""
    candidates = [REPO_ROOT / "tests", Path.cwd() / "tests"]
    for p in paths:
        candidates.append(Path(p).resolve().parent / "tests")
    for c in candidates:
        if c.is_dir():
            return c
    return None


def selftest() -> int:
    """Every rule must trip on its committed fixture — the proof the
    rules are alive — and src/ must be clean."""
    if not FIXTURES.is_dir():
        print(f"selftest: fixture tree missing: {FIXTURES}", file=sys.stderr)
        return 1
    findings = lint.lint_paths([FIXTURES], tests_dir=None)
    tripped = {f.rule for f in findings}
    expected = set(lint.RULES)
    ok = True
    for rule in sorted(expected):
        n = sum(1 for f in findings if f.rule == rule)
        status = "TRIP" if rule in tripped else "MISS"
        print(f"  {rule}: {status} ({n} finding{'s' if n != 1 else ''})")
        if rule not in tripped:
            ok = False
    if not ok:
        print("selftest: FAILED — a rule no longer trips on its fixture")
        for f in findings:
            print(f"  {f}")
        return 1
    src_findings = lint.lint_paths(
        [REPO_ROOT / "src"], tests_dir=REPO_ROOT / "tests"
    )
    if src_findings:
        print("selftest: FAILED — src/ must be finding-free:")
        for f in src_findings:
            print(f"  {f}")
        return 1
    print(
        f"selftest: OK — all {len(expected)} rules trip on fixtures "
        f"({len(findings)} findings), src/ clean"
    )
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="seclint", description=__doc__.splitlines()[0]
    )
    ap.add_argument(
        "paths",
        nargs="*",
        help="files or trees to lint (default: the repo's src/)",
    )
    ap.add_argument(
        "--tests-dir",
        type=Path,
        default=None,
        help="tests directory for the SEC004 kernel-test check "
        "(auto-detected; pass an empty string to disable)",
    )
    ap.add_argument(
        "--selftest",
        action="store_true",
        help="lint the bad fixtures and require every rule to trip",
    )
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule, desc in sorted(lint.RULES.items()):
            print(f"{rule}  {desc}")
        return 0
    if args.selftest:
        return selftest()

    paths = [Path(p) for p in args.paths] or [REPO_ROOT / "src"]
    for p in paths:
        if not p.exists():
            print(f"seclint: no such path: {p}", file=sys.stderr)
            return 2
    tests_dir = args.tests_dir
    if tests_dir is None:
        tests_dir = _default_tests_dir(paths)
    elif str(tests_dir) == "":
        tests_dir = None

    findings = lint.lint_paths(paths, tests_dir=tests_dir)
    for f in findings:
        print(f)
    if findings:
        print(f"seclint: {len(findings)} finding(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
